"""Replicated-engine router at EQUAL TOTAL HBM: scaling + affinity.

The claim under test (PR 8 / ROADMAP "Scale-out"): one continuous-
batching engine stops scaling at its slot count, and the fix — N
replicated engines behind a router — only preserves the prefix-cache
economics if placement is prefix-aware. Random (pure least-loaded)
routing sprays each hot retrieved context across all replicas: every
replica re-publishes its own copy, the first request per (context,
replica) pays a full prefill, and the duplicated KV churns each
replica's smaller retention budget. Prefix-affinity placement routes
requests sharing a context to the replica already holding it, so each
context is published once fleet-wide.

Every cell gets the same TOTAL device HBM and the same per-engine
geometry — the single-engine cell's pool and retention budgets are N x
the per-replica budgets:

  single     EngineRouter(n_replicas=1), N x pool blocks, N x retention
  random     EngineRouter(n_replicas=N, affinity=False)
  affinity   EngineRouter(n_replicas=N, affinity=True)

Requests replay the same Zipf-sampled greedy burst in open-loop waves
(each wave submitted through the router before any engine runs, then
drained between waves so publishers retire and only retention carries
KV across arrivals). This host has one core, so fleet parallelism is
simulated honestly: each replica's drain is timed independently and the
fleet's per-wave wall-clock is the MAX over replicas — exactly the
wall-clock N independent devices would see. Gates: aggregate decode
throughput must scale vs the single engine, affinity routing must
preserve the prefix hit rate that random routing collapses, and greedy
token parity vs per-query `GenerationEngine.generate` must hold in
every cell.

Compute runs in fp32 (`compute_dtype` override) for the same reason as
bench_prefix_sharing: parity across differently-batched reduction
orders needs fp32 headroom over the untrained smoke model's logit
near-ties.

Emits BENCH_router.json (rows + config) for the CI perf artifact.

Run: PYTHONPATH=src python -m benchmarks.bench_router [--tiny]
         [--out BENCH_router.json]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving import (
    EngineConfig,
    EngineRouter,
    GenerationEngine,
    RouterConfig,
)

FULL = {
    "arch": "phi4-mini-3.8b",
    "cache_len": 96,
    "n_slots": 4,
    "block_size": 8,
    "prefill_chunk": 16,
    "n_replicas": 2,
    "pool_blocks": 32,  # usable device blocks PER REPLICA
    "retain_blocks": 16,  # retention budget PER REPLICA (2 contexts)
    "n_contexts": 4,
    "zipf_s": 1.2,
    "n_requests": 24,
    "wave": 8,  # requests submitted through the router per wave
    "context_tokens": 64,  # the shared head: 8 full blocks per context
    "suffix_tokens": 8,
    "new_tokens": 8,
    "repeats": 2,
    "min_scaling": 1.15,  # affinity fleet tok/s / single tok/s
    "min_hit_gap": 0.10,  # affinity hit rate - random hit rate
    "max_hit_drop": 0.05,  # single hit rate - affinity hit rate
}

TINY = {
    "arch": "phi4-mini-3.8b",
    "cache_len": 48,
    "n_slots": 2,
    "block_size": 8,
    "prefill_chunk": 8,
    "n_replicas": 2,
    "pool_blocks": 12,
    "retain_blocks": 2,  # fits 1 of the 2 contexts
    "n_contexts": 2,
    "zipf_s": 0.0,
    "n_requests": 8,
    "wave": 4,
    "context_tokens": 16,  # 2 full blocks per context
    "suffix_tokens": 4,
    "new_tokens": 4,
    "repeats": 1,
    "min_scaling": 0.0,  # smoke shapes are too noisy for a scaling gate
    "min_hit_gap": 0.0,
    "max_hit_drop": 1.0,
}

CELLS = (
    # label, n_replicas factor on budgets, fleet size, affinity
    ("single", "single", True),
    ("random", "fleet", False),
    ("affinity", "fleet", True),
)


def _workload(bench_cfg: dict):
    """Zipf-sampled (prompt, max_new, prefix_len) burst: `n_contexts`
    fixed full-block contexts, rank-r context drawn with p ~ 1/r^s,
    every suffix unique. Wave boundaries are the caller's job."""
    cfg = get_config(bench_cfg["arch"], smoke=True)
    rng = np.random.default_rng(0)
    ctx_len = bench_cfg["context_tokens"]
    contexts = [
        rng.integers(0, cfg.vocab_size, size=ctx_len).astype(np.int32)
        for _ in range(bench_cfg["n_contexts"])
    ]
    w = 1.0 / np.arange(1, bench_cfg["n_contexts"] + 1) ** bench_cfg["zipf_s"]
    picks = rng.choice(bench_cfg["n_contexts"], size=bench_cfg["n_requests"],
                       p=w / w.sum())
    reqs = []
    for i in picks:
        sfx = rng.integers(
            0, cfg.vocab_size, size=bench_cfg["suffix_tokens"]
        ).astype(np.int32)
        reqs.append((
            np.concatenate([contexts[i], sfx]),
            bench_cfg["new_tokens"],
            ctx_len,
        ))
    return reqs


def _make_router(model, params, bench_cfg: dict, label: str):
    """One cell's fleet at equal TOTAL HBM: the single-engine cell gets
    n_replicas x the per-replica pool and retention budgets."""
    n = bench_cfg["n_replicas"]
    scale = n if label == "single" else 1
    fleet = 1 if label == "single" else n
    affinity = dict((lbl, aff) for lbl, _, aff in CELLS)[label]
    return EngineRouter(
        model, params,
        EngineConfig(
            n_slots=bench_cfg["n_slots"],
            cache_len=bench_cfg["cache_len"],
            paged=True,
            block_size=bench_cfg["block_size"],
            n_blocks=scale * bench_cfg["pool_blocks"] + 1,  # + the null block
            prefill_chunk=bench_cfg["prefill_chunk"],
            prefix_sharing=True,
            retain_blocks=scale * bench_cfg["retain_blocks"],
        ),
        RouterConfig(n_replicas=fleet, affinity=affinity),
    )


def _replay(router, reqs, wave: int):
    """Submit each wave through the router before any engine runs, then
    drain every replica under its OWN timer: per-wave fleet wall-clock
    is the max over replicas (what N independent devices would see),
    and draining between waves retires publishers so only retention
    carries context KV across arrivals. Returns (tickets, fleet_wall)."""
    tickets, fleet_wall = [], 0.0
    for lo in range(0, len(reqs), wave):
        tickets += [router.submit(p, max_new_tokens=new, prefix_len=h)
                    for p, new, h in reqs[lo:lo + wave]]
        walls = []
        for rep in router.engines:
            t0 = time.perf_counter()
            rep.run_until_drained()
            walls.append(time.perf_counter() - t0)
        fleet_wall += max(walls)
    return tickets, fleet_wall


def _pool_delta(pre: dict, post: dict, key: str) -> int:
    return sum(e["pool"][key] for e in post["replicas"]) - \
        sum(e["pool"][key] for e in pre["replicas"])


def _bench_cell(router, reqs, refs, wave: int, repeats: int) -> dict:
    """Warm-up pass (compile every shape per replica), then
    `clear_prefix_cache()` + replay; keep the best-throughput measured
    pass by counter deltas."""
    _replay(router, reqs, wave)
    best_tps, best = 0.0, None
    for _ in range(repeats):
        router.clear_prefix_cache()
        pre = router.stats()
        tickets, fleet_wall = _replay(router, reqs, wave)
        outs = [np.asarray(t.result()) for t in tickets]
        tps = sum(len(o) for o in outs) / fleet_wall
        if tps > best_tps or best is None:
            best_tps, best = tps, (tickets, outs, fleet_wall, pre,
                                   router.stats())
    tickets, outs, fleet_wall, pre, post = best
    parity = all(np.array_equal(a, b) for a, b in zip(refs, outs))
    hits = _pool_delta(pre, post, "n_prefix_hits")
    misses = _pool_delta(pre, post, "n_prefix_misses")
    lookups = hits + misses
    return {
        "n_requests": len(reqs),
        "n_tokens": int(sum(len(o) for o in outs)),
        "tok_per_s": best_tps,
        "fleet_wall_s": fleet_wall,
        "parity": parity,
        "n_prefix_hits": hits,
        "n_prefix_misses": misses,
        "hit_rate": (hits / lookups) if lookups else 0.0,
        "n_evictions": _pool_delta(pre, post, "n_evictions"),
        "per_replica_submits": [
            b - a for a, b in zip(pre["per_replica_submits"],
                                  post["per_replica_submits"])
        ],
        "n_affinity_hits": post["n_affinity_hits"] - pre["n_affinity_hits"],
        "n_affinity_spills": (post["n_affinity_spills"]
                              - pre["n_affinity_spills"]),
    }


def run(bench_cfg: dict) -> list[dict]:
    cfg = dataclasses.replace(
        get_config(bench_cfg["arch"], smoke=True),
        compute_dtype="float32",
    )
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    baseline = GenerationEngine(model, params)
    reqs = _workload(bench_cfg)
    refs = []
    for p, new, _ in reqs:
        out = baseline.generate(
            np.asarray(p)[None], max_new_tokens=new, cache_len=len(p) + new)
        refs.append(np.asarray(out)[0])

    rows = []
    for label, _, affinity in CELLS:
        router = _make_router(model, params, bench_cfg, label)
        row = _bench_cell(router, reqs, refs, bench_cfg["wave"],
                          bench_cfg.get("repeats", 2))
        row["cell"] = label
        row["n_replicas"] = router.n_replicas
        row["affinity"] = affinity
        row["pool_blocks_per_engine"] = (
            router.config.n_blocks - 1)
        row["retain_blocks_per_engine"] = router.config.retain_blocks
        row["total_pool_blocks"] = (
            router.n_replicas * (router.config.n_blocks - 1))
        row["block_size"] = bench_cfg["block_size"]
        rows.append(row)
        router.close()
    return rows


def _cell(rows, cell: str) -> dict:
    for r in rows:
        if r["cell"] == cell:
            return r
    raise KeyError(cell)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true", help="CI smoke shapes")
    ap.add_argument("--out", default="BENCH_router.json")
    args = ap.parse_args(argv)
    cfg = TINY if args.tiny else FULL
    rows = run(cfg)

    print("cell,replicas,affinity,tok_per_s,hit_rate,submits,spills,parity")
    for r in rows:
        print(f"{r['cell']},{r['n_replicas']},{r['affinity']},"
              f"{r['tok_per_s']:.0f},{r['hit_rate']:.2f},"
              f"{'/'.join(map(str, r['per_replica_submits']))},"
              f"{r['n_affinity_spills']},{r['parity']}")

    bad = [r for r in rows if not r["parity"]]
    if bad:
        raise SystemExit(f"greedy parity violated in {len(bad)} cells")
    single = _cell(rows, "single")
    random_, aff = _cell(rows, "random"), _cell(rows, "affinity")
    scaling = (aff["tok_per_s"] / single["tok_per_s"]
               if single["tok_per_s"] else 0.0)
    hit_gap = aff["hit_rate"] - random_["hit_rate"]
    hit_drop = single["hit_rate"] - aff["hit_rate"]
    print(f"aggregate decode scaling at equal total HBM: "
          f"{single['tok_per_s']:.0f} -> {aff['tok_per_s']:.0f} tok/s "
          f"({scaling:.2f}x over 1 replica)")
    print(f"prefix hit rate: single {single['hit_rate']:.2f}, random "
          f"{random_['hit_rate']:.2f} (collapse), affinity "
          f"{aff['hit_rate']:.2f} (gap +{hit_gap:.2f})")
    if scaling < cfg["min_scaling"]:
        raise SystemExit(
            f"fleet scaling {scaling:.2f}x < {cfg['min_scaling']}x "
            f"at equal total HBM")
    if hit_gap < cfg["min_hit_gap"]:
        raise SystemExit(
            f"affinity hit-rate gap over random routing {hit_gap:.2f} "
            f"< {cfg['min_hit_gap']}")
    if hit_drop > cfg["max_hit_drop"]:
        raise SystemExit(
            f"affinity lost {hit_drop:.2f} hit rate vs the single engine "
            f"(> {cfg['max_hit_drop']})")

    with open(args.out, "w") as f:
        json.dump({"config": dict(cfg), "rows": rows}, f, indent=1)
    print(f"wrote {args.out} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
