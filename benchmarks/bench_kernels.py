"""Kernel micro-benchmarks (interpret mode on CPU: correctness + relative
cost; absolute TPU numbers come from the roofline analysis)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitplane as B
from repro.kernels import ops


def _time(fn, *args, reps=3):
    out = fn(*args)
    jax.tree_util.tree_map(
        lambda x: x.block_until_ready() if hasattr(x, "block_until_ready")
        else x, out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        jax.tree_util.tree_map(
            lambda x: x.block_until_ready() if hasattr(x, "block_until_ready")
            else x, out)
    return (time.perf_counter() - t0) / reps * 1e6


def run() -> list:
    rng = np.random.default_rng(0)
    n, dim = 2048, 512
    q = jnp.asarray(rng.integers(-128, 128, size=(1, dim)), jnp.int8)
    d = jnp.asarray(rng.integers(-128, 128, size=(n, dim)), jnp.int8)
    packed = B.pack_words(B.to_bitplanes(d))
    dn = jnp.sqrt(jnp.sum(d.astype(jnp.float32) ** 2, -1))
    scores = jnp.asarray(rng.normal(size=(1, n)).astype(np.float32))
    rows = [
        {"kernel": "dirc_mac(bitserial, paper-faithful)",
         "us_per_call": _time(ops.dirc_mac, q, packed),
         "work": f"{n}x{dim} int8 docs"},
        {"kernel": "score_matmul(MXU path, beyond-paper)",
         "us_per_call": _time(ops.score_matmul, q, d),
         "work": f"{n}x{dim} int8 docs"},
        {"kernel": "score_matmul_cosine(fused)",
         "us_per_call": _time(ops.score_matmul_cosine, q, d, dn),
         "work": f"{n}x{dim} int8 docs"},
        {"kernel": "local_topk_blocks(k=16)",
         "us_per_call": _time(lambda s: ops.local_topk_blocks(s, 16), scores),
         "work": f"{n} scores"},
    ]
    return rows


def main() -> None:
    print("kernel,us_per_call,work")
    for r in run():
        print(f"{r['kernel']},{r['us_per_call']:.1f},{r['work']}")


if __name__ == "__main__":
    main()
