"""Continuous-batching decode throughput vs the per-query baseline.

Sweeps `n_slots x offered-load x max_new_tokens`: each cell submits
`load` concurrent generation requests (fixed prompt length, greedy) to a
`ContinuousBatchingEngine` and measures decode tokens/sec against the PR 2
per-query baseline — the same requests served one at a time by
`GenerationEngine.generate` (b=1), which is exactly what
`RagPipeline.query_many` did before PR 3. Every cell also checks greedy
parity: the engine's emitted tokens must equal the baseline token-for-token
(up to EOS), so the speedup is never bought with different outputs.

The story this charts: with slot-based iteration-level scheduling the
decode batch stays full as requests join/leave at token boundaries, so at
offered load >= 2 the batched `decode_step` amortizes per-step overhead
that b=1 serving pays per request.

Emits BENCH_continuous_batching.json (rows + config) for the CI perf
artifact.

Run: PYTHONPATH=src python -m benchmarks.bench_continuous_batching [--tiny]
         [--out BENCH_continuous_batching.json]
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving import ContinuousBatchingEngine, GenerationEngine

FULL = {
    "arch": "phi4-mini-3.8b",
    "prompt_len": 32,
    "slots": (2, 4, 8),
    "loads": (1, 2, 4, 8),
    "new_tokens": (16, 64),
    "repeats": 3,
}

TINY = {
    "arch": "phi4-mini-3.8b",
    "prompt_len": 16,
    "slots": (2, 4),
    "loads": (1, 2, 4),
    "new_tokens": (8,),
    "repeats": 3,
}


def _prompts(cfg, n: int, prompt_len: int) -> list[np.ndarray]:
    rng = np.random.default_rng(0)
    return [rng.integers(0, cfg.vocab_size, size=prompt_len).astype(np.int32)
            for _ in range(n)]


def _trim_eos(row: np.ndarray, eos_id: int) -> np.ndarray:
    hits = np.where(row == eos_id)[0]
    return row[: hits[0] + 1] if hits.size else row


def run(bench_cfg: dict) -> list[dict]:
    cfg = get_config(bench_cfg["arch"], smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    eos_id = 258  # ByteTokenizer EOS; an untrained model rarely emits it
    baseline = GenerationEngine(model, params)
    max_load = max(bench_cfg["loads"])
    prompts = _prompts(cfg, max_load, bench_cfg["prompt_len"])
    # this container's CPU timings are noisy: take the best of `repeats`
    # timed passes for BOTH sides (outputs are greedy, so identical)
    repeats = bench_cfg.get("repeats", 3)

    base_cache: dict[tuple, tuple] = {}

    def per_query_baseline(load: int, max_new: int):
        """Serve `load` requests one at a time at b=1 (PR 2 behaviour)."""
        key = (load, max_new)
        if key not in base_cache:
            cache_len = bench_cfg["prompt_len"] + max_new

            def gen(p):
                return baseline.generate(
                    np.asarray(p)[None], max_new_tokens=max_new,
                    cache_len=cache_len, eos_id=eos_id)

            gen(prompts[0])  # compile off-clock
            best = 0.0
            for _ in range(repeats):
                t0 = time.perf_counter()
                outs = [gen(p)[0] for p in prompts[:load]]
                dt = time.perf_counter() - t0
                outs = [_trim_eos(o, eos_id) for o in outs]
                best = max(best, sum(len(o) for o in outs) / dt)
            base_cache[key] = (outs, best)
        return base_cache[key]

    rows = []
    for n_slots in bench_cfg["slots"]:
        for max_new in bench_cfg["new_tokens"]:
            cache_len = bench_cfg["prompt_len"] + max_new
            for load in bench_cfg["loads"]:
                engine = ContinuousBatchingEngine(
                    model, params, n_slots=n_slots, cache_len=cache_len,
                    eos_id=eos_id)
                # compile prefill + the (n_slots, 1) decode step off-clock
                engine.submit(prompts[0], max_new_tokens=max_new).result()
                best_tps, outs = 0.0, None
                n_steps, mean_occ = 0, 0.0
                for _ in range(repeats):
                    pre = engine.stats()
                    t0 = time.perf_counter()
                    tickets = [engine.submit(p, max_new_tokens=max_new)
                               for p in prompts[:load]]
                    engine.run_until_drained()
                    dt = time.perf_counter() - t0
                    run_outs = [t.result() for t in tickets]
                    tps = sum(len(o) for o in run_outs) / dt
                    post = engine.stats()
                    if tps > best_tps or outs is None:
                        best_tps, outs = tps, run_outs
                        # per-run occupancy (the counters accumulate
                        # across the warm-up and every repeat)
                        n_steps = (post["n_decode_steps"]
                                   - pre["n_decode_steps"])
                        occ_tokens = sum(
                            occ * (n - pre["occupancy_hist"].get(occ, 0))
                            for occ, n in post["occupancy_hist"].items())
                        mean_occ = occ_tokens / n_steps if n_steps else 0.0
                base_outs, base_tps = per_query_baseline(load, max_new)
                parity = all(np.array_equal(a, b)
                             for a, b in zip(base_outs, outs))
                n_tokens = sum(len(o) for o in outs)
                rows.append({
                    "n_slots": n_slots,
                    "load": load,
                    "max_new_tokens": max_new,
                    "n_tokens": n_tokens,
                    "cb_tok_per_s": best_tps,
                    "base_tok_per_s": base_tps,
                    "speedup": best_tps / base_tps,
                    "parity": parity,
                    "n_decode_steps": n_steps,
                    "mean_occupancy": mean_occ,
                })
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true", help="CI smoke shapes")
    ap.add_argument("--out", default="BENCH_continuous_batching.json")
    args = ap.parse_args(argv)
    cfg = TINY if args.tiny else FULL
    rows = run(cfg)

    print("n_slots,load,max_new,cb_tok_per_s,base_tok_per_s,speedup,"
          "mean_occupancy,parity")
    for r in rows:
        print(f"{r['n_slots']},{r['load']},{r['max_new_tokens']},"
              f"{r['cb_tok_per_s']:.0f},{r['base_tok_per_s']:.0f},"
              f"{r['speedup']:.2f},{r['mean_occupancy']:.2f},{r['parity']}")
    bad = [r for r in rows if not r["parity"]]
    if bad:
        raise SystemExit(f"greedy parity violated in {len(bad)} cells")
    cfg_json = {k: list(v) if isinstance(v, tuple) else v
                for k, v in cfg.items()}
    with open(args.out, "w") as f:
        json.dump({"config": cfg_json, "rows": rows}, f, indent=1)
    print(f"wrote {args.out} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
