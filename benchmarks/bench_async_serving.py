"""Async streaming serving: latency percentiles and achieved batch size
vs offered load, flush deadline, and tenant count.

Sweeps `max_wait_ms x offered-qps x n_tenants` over the open-loop Poisson
traffic driver (`repro.launch.serve.serve_rag_open_loop`): every config
replays a stream of single-query arrivals into the AsyncBatchScheduler's
background flush loop and records p50/p95/p99 submit->serve latency, the
achieved batch-size histogram, and per-tenant p95 under a 10:1 skew
(tenant 0 is the chatty one). The tradeoff this charts is the paper's
query-stationary batching story under ONLINE traffic: a larger deadline
buys fuller (b, dim) batches for the macro at the cost of tail latency.

Emits BENCH_async_serving.json (rows + config) for the CI perf artifact.

Run: PYTHONPATH=src python -m benchmarks.bench_async_serving [--tiny]
         [--out BENCH_async_serving.json]
"""

from __future__ import annotations

import argparse
import json

from repro.launch.serve import build_rag_pipeline, serve_rag_open_loop

FULL = {
    "n_docs": 1024,
    "dim": 256,
    "n_shards": 4,
    "max_batch": 16,
    "n_queries": 200,
    "waits_ms": (1.0, 5.0, 20.0),
    "loads_qps": (100.0, 400.0, 1200.0),
    "tenants": (1, 4),
    "skew": 10.0,
}

TINY = {
    "n_docs": 128,
    "dim": 128,
    "n_shards": 2,
    "max_batch": 8,
    "n_queries": 48,
    "waits_ms": (2.0, 10.0),
    "loads_qps": (200.0, 800.0),
    "tenants": (1, 4),
    "skew": 10.0,
}


def run(cfg: dict) -> list[dict]:
    pipe = build_rag_pipeline(
        n_docs=cfg["n_docs"], n_shards=cfg["n_shards"], dim=cfg["dim"], seed=0
    )
    rows = []
    for n_tenants in cfg["tenants"]:
        for wait_ms in cfg["waits_ms"]:
            for qps in cfg["loads_qps"]:
                rows.append(
                    serve_rag_open_loop(
                        max_batch=cfg["max_batch"],
                        max_wait_ms=wait_ms,
                        n_tenants=n_tenants,
                        skew=cfg["skew"] if n_tenants > 1 else 1.0,
                        offered_qps=qps,
                        n_queries=cfg["n_queries"],
                        pipe=pipe,
                    )
                )
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true", help="CI smoke shapes")
    ap.add_argument("--out", default="BENCH_async_serving.json")
    args = ap.parse_args(argv)
    cfg = TINY if args.tiny else FULL
    rows = run(cfg)

    print(
        "n_tenants,max_wait_ms,offered_qps,achieved_qps,"
        "p50_ms,p95_ms,p99_ms,mean_batch"
    )
    for r in rows:
        print(
            f"{r['n_tenants']},{r['max_wait_ms']},{r['offered_qps']:.0f},"
            f"{r['achieved_qps']:.0f},{r['p50_ms']:.2f},{r['p95_ms']:.2f},"
            f"{r['p99_ms']:.2f},{r['mean_batch']:.2f}"
        )
    cfg_json = {k: list(v) if isinstance(v, tuple) else v for k, v in cfg.items()}
    with open(args.out, "w") as f:
        json.dump({"config": cfg_json, "rows": rows}, f, indent=1)
    print(f"wrote {args.out} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
