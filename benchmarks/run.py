"""Benchmark harness: one module per paper table/figure.

  bench_simulator  -> Table I   (spec + scaling)
  bench_precision  -> Table II  (P@k at FP32/INT8/INT4)
  bench_latency    -> Table III (DIRC vs baselines)
  bench_error_opt  -> Fig. 6    (error-aware optimization ladder)
  bench_kernels    -> kernel micro-benchmarks
  bench_sharded    -> multi-macro sharded retrieval throughput
  bench_async_serving -> open-loop streaming latency vs flush deadline
  bench_continuous_batching -> decode throughput vs per-query generation
  bench_paged_cache -> paged vs fixed-slot KV cache at equal HBM
  bench_prefix_sharing -> CoW prefix sharing vs private blocks at equal HBM
  bench_prefix_cache -> tiered prefix retention + host offload, Zipf sweep
  bench_router     -> replicated-engine fleet scaling + prefix affinity
  bench_slo        -> SLO controller + priority preemption vs static knobs
  bench_drift      -> temporal drift vs the online recalibration loop
  roofline_report  -> dry-run roofline tables (EXPERIMENTS.md source)

Run: PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import time

from . import (bench_async_serving, bench_continuous_batching,
               bench_drift, bench_error_opt, bench_kernels, bench_latency,
               bench_paged_cache, bench_precision, bench_prefix_cache,
               bench_prefix_sharing, bench_router, bench_sharded,
               bench_simulator, bench_slo, roofline_report)

SECTIONS = [
    ("Table I — DIRC-RAG spec (calibrated model)", bench_simulator),
    ("Table II — retrieval precision vs quantization", bench_precision),
    ("Table III — latency/energy vs baselines", bench_latency),
    ("Fig. 6 — error-aware optimization ladder", bench_error_opt),
    ("Kernel micro-benchmarks", bench_kernels),
    ("Sharded multi-macro throughput", bench_sharded),
    ("Async open-loop serving latency", bench_async_serving),
    ("Continuous-batching decode throughput", bench_continuous_batching),
    ("Paged vs fixed-slot KV cache", bench_paged_cache),
    ("CoW prefix sharing on the paged pool", bench_prefix_sharing),
    ("Tiered prefix retention + host offload", bench_prefix_cache),
    ("Replicated-engine fleet + prefix affinity", bench_router),
    ("SLO controller + priority preemption", bench_slo),
    ("Drift vs the online recalibration loop", bench_drift),
    ("Roofline (from multi-pod dry-run)", roofline_report),
]


def main() -> None:
    for title, mod in SECTIONS:
        print(f"\n{'=' * 72}\n== {title}\n{'=' * 72}")
        t0 = time.time()
        try:
            mod.main()
        except Exception as e:  # noqa: BLE001
            print(f"SECTION FAILED: {type(e).__name__}: {e}")
        print(f"-- section took {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
