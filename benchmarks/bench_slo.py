"""SLO control plane under a two-priority diurnal+bursty mix.

The claim under test (PR 10 / ROADMAP "Self-tuning serving control
plane"): static serving knobs cannot hold a tail-latency target when
low-priority batch jobs share the paged KV pool with latency-sensitive
traffic — a long batch decode parks 6 of the pool's 7 usable blocks and
every "pro" arrival that lands inside that window queues for the full
residual service time. The SLO controller closes the loop: it polls the
engine's completion feed on the serving clock and, under real pool
pressure, preempts a strictly-lower-priority victim (publish resident
KV to the retained tier -> release blocks -> re-queue; resume re-attaches
and re-prefills only what eviction took), so pro requests admit in one
step instead of one batch-job service time.

Both cells of every load point replay the IDENTICAL arrival trace on a
virtual clock (the engine and controller both run on the injected fake
clock), so the comparison is pure policy — no host noise, no compile
skew, bit-reproducible:

  static   engine alone: priority-aware admission, no controller
  slo      + SLOController(preempt=True) polled once per 10ms tick

Arrivals: per-class Poisson gaps modulated by a diurnal sinusoid, with
pro traffic additionally arriving in bursts; load cells scale the
offered rate. Gates: pro-class SLO attainment under the controller must
beat static by `min_attain_gap` at EVERY load cell, at least
`min_preemptions` preemptions must actually fire, and every completed
request in every cell must match per-query `GenerationEngine.generate`
token-for-token (preempt/resume is only admissible if it is invisible
in the tokens).

Compute runs in fp32 (`compute_dtype` override) for the same reason as
bench_router: greedy parity across differently-batched reduction orders
needs fp32 headroom over the untrained smoke model's logit near-ties.

Emits BENCH_slo.json (rows + config) for the CI perf artifact.

Run: PYTHONPATH=src python -m benchmarks.bench_slo [--tiny]
         [--out BENCH_slo.json]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import math
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving import (
    ContinuousBatchingEngine,
    EngineConfig,
    GenerationEngine,
    SLOConfig,
    SLOController,
)

FULL = {
    "arch": "phi4-mini-3.8b",
    "cache_len": 64,
    "n_slots": 2,
    "block_size": 8,
    "pool_blocks": 7,  # usable blocks: one batch job parks 6 of them
    "retain_blocks": 6,  # a preempted batch prefix survives on-device
    "prefill_chunk": 16,
    "step_ms": 10.0,  # virtual cost of one engine.step()
    "horizon_s": 6.0,  # arrival window (virtual); drain runs past it
    "diurnal_amp": 0.5,
    "diurnal_period_s": 3.0,
    "loads": [1.0, 1.5],
    "pro": {"prompt": 8, "new": 4, "mean_gap_s": 0.18,
            "burst_p": 0.25, "burst_n": 3, "burst_gap_s": 0.02},
    "batch": {"prompt": 32, "new": 16, "mean_gap_s": 0.5},
    "pro_target_ms": 120.0,
    "batch_target_ms": 2000.0,
    "min_attain_gap": 0.05,  # slo attainment - static attainment, per cell
    "min_preemptions": 1,
    "max_steps": 20000,
}

TINY = {
    "arch": "phi4-mini-3.8b",
    "cache_len": 64,
    "n_slots": 2,
    "block_size": 8,
    "pool_blocks": 7,
    "retain_blocks": 6,
    "prefill_chunk": 16,
    "step_ms": 10.0,
    "horizon_s": 1.5,
    "diurnal_amp": 0.5,
    "diurnal_period_s": 1.0,
    "loads": [1.0],
    "pro": {"prompt": 8, "new": 4, "mean_gap_s": 0.15,
            "burst_p": 0.25, "burst_n": 2, "burst_gap_s": 0.02},
    "batch": {"prompt": 32, "new": 16, "mean_gap_s": 0.35},
    "pro_target_ms": 120.0,
    "batch_target_ms": 2000.0,
    "min_attain_gap": 0.0,  # smoke shapes: still must not be WORSE
    "min_preemptions": 0,
    "max_steps": 20000,
}


class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, s):
        self.t += s


def _workload(bench_cfg: dict, load: float, vocab: int):
    """One arrival trace shared by both policy cells of a load point.

    Per-class Poisson gaps, thinned by the diurnal sinusoid (peak-hour
    arrivals cluster); pro arrivals additionally fork into short bursts.
    Returns [(t, cls, priority, prompt, max_new)] sorted by t."""
    rng = np.random.default_rng(7 + int(load * 100))
    arrivals = []

    def emit(t, cls, priority):
        spec = bench_cfg[cls]
        prompt = rng.integers(0, vocab, size=spec["prompt"]).astype(np.int32)
        arrivals.append((t, cls, priority, prompt, spec["new"]))

    for cls, priority in (("batch", 0), ("pro", 1)):
        spec = bench_cfg[cls]
        t = rng.exponential(spec["mean_gap_s"] / load)
        while t < bench_cfg["horizon_s"]:
            diurnal = 1.0 + bench_cfg["diurnal_amp"] * math.sin(
                2 * math.pi * t / bench_cfg["diurnal_period_s"])
            if rng.uniform() < diurnal / (1.0 + bench_cfg["diurnal_amp"]):
                emit(t, cls, priority)
                if cls == "pro" and rng.uniform() < spec["burst_p"]:
                    for j in range(1, spec["burst_n"]):
                        emit(t + j * spec["burst_gap_s"], cls, priority)
            t += rng.exponential(spec["mean_gap_s"] / load)
    arrivals.sort(key=lambda a: a[0])
    return arrivals


def _engine_config(bench_cfg: dict) -> EngineConfig:
    return EngineConfig(
        n_slots=bench_cfg["n_slots"],
        cache_len=bench_cfg["cache_len"],
        paged=True,
        block_size=bench_cfg["block_size"],
        n_blocks=bench_cfg["pool_blocks"] + 1,  # + the null block
        prefill_chunk=bench_cfg["prefill_chunk"],
        prefix_sharing=True,
        retain_blocks=bench_cfg["retain_blocks"],
    )


def _simulate(model, params, bench_cfg: dict, arrivals, policy: str):
    """Replay one arrival trace on the virtual clock; returns the
    completed (cls, ticket) records plus engine/controller counters."""
    clock = _FakeClock()
    eng = ContinuousBatchingEngine(model, params, _engine_config(bench_cfg),
                                   clock=clock)
    ctrl = None
    if policy == "slo":
        ctrl = SLOController(
            SLOConfig(
                e2e_p95_ms=bench_cfg["batch_target_ms"],
                tenant_e2e_p95_ms={"pro": bench_cfg["pro_target_ms"]},
                window_s=2.0, interval_s=0.05, min_samples=4,
                preempt=True, max_preemptions_per_poll=1,
            ),
            engine=eng, clock=clock)
    step_s = bench_cfg["step_ms"] / 1e3
    recs, i, steps = [], 0, 0
    t_wall = time.perf_counter()
    while i < len(arrivals) or not all(t.done() for _, t in recs):
        while i < len(arrivals) and arrivals[i][0] <= clock.t:
            _, cls, priority, prompt, max_new = arrivals[i]
            recs.append((cls, eng.submit(prompt, max_new_tokens=max_new,
                                         tenant=cls, priority=priority)))
            i += 1
        eng.step()
        clock.advance(step_s)
        if ctrl is not None:
            ctrl.poll()
        steps += 1
        if steps > bench_cfg["max_steps"]:
            raise SystemExit(
                f"{policy} cell did not drain within "
                f"{bench_cfg['max_steps']} steps — pool livelock?")
    est = eng.stats()
    cst = ctrl.stats() if ctrl is not None else None
    if ctrl is not None:
        ctrl.close()
    eng.close()
    wall_s = time.perf_counter() - t_wall
    return recs, est, cst, steps, clock.t, wall_s


def _attainment(recs, cls: str, target_ms: float):
    lat = [t.wait_s * 1e3 for c, t in recs if c == cls]
    met = sum(1 for v in lat if v <= target_ms)
    arr = np.asarray(lat, np.float64)
    p95 = float(np.percentile(arr, 95)) if arr.size else 0.0
    return (met / len(lat) if lat else 1.0), p95, len(lat)


def run(bench_cfg: dict) -> list[dict]:
    cfg = dataclasses.replace(
        get_config(bench_cfg["arch"], smoke=True),
        compute_dtype="float32",
    )
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    baseline = GenerationEngine(model, params)

    rows = []
    for load in bench_cfg["loads"]:
        arrivals = _workload(bench_cfg, load, cfg.vocab_size)
        refs = []
        for _, _, _, prompt, max_new in arrivals:
            out = baseline.generate(np.asarray(prompt)[None],
                                    max_new_tokens=max_new,
                                    cache_len=len(prompt) + max_new)
            refs.append(np.asarray(out)[0])
        for policy in ("static", "slo"):
            recs, est, cst, steps, virtual_s, wall_s = _simulate(
                model, params, bench_cfg, arrivals, policy)
            parity = all(
                np.array_equal(np.asarray(t.result()), ref)
                for (_, t), ref in zip(recs, refs))
            pro_att, pro_p95, n_pro = _attainment(
                recs, "pro", bench_cfg["pro_target_ms"])
            batch_att, batch_p95, n_batch = _attainment(
                recs, "batch", bench_cfg["batch_target_ms"])
            rows.append({
                "cell": f"load{load:g}-{policy}",
                "load": load,
                "policy": policy,
                "n_pro": n_pro,
                "n_batch": n_batch,
                "pro_target_ms": bench_cfg["pro_target_ms"],
                "pro_attainment": pro_att,
                "pro_p95_ms": pro_p95,
                "batch_attainment": batch_att,
                "batch_p95_ms": batch_p95,
                "n_preemptions": est["n_preemptions"],
                "n_resumes": est["n_resumes"],
                "n_weight_updates": (cst["n_weight_updates"]
                                     if cst else 0),
                "n_polls": cst["n_polls"] if cst else 0,
                "parity": parity,
                "steps": steps,
                "virtual_s": virtual_s,
                "wall_s": wall_s,
            })
    return rows


def _cell(rows, load: float, policy: str) -> dict:
    for r in rows:
        if r["load"] == load and r["policy"] == policy:
            return r
    raise KeyError((load, policy))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true", help="CI smoke shapes")
    ap.add_argument("--out", default="BENCH_slo.json")
    args = ap.parse_args(argv)
    cfg = TINY if args.tiny else FULL
    rows = run(cfg)

    print("cell,n_pro,pro_attain,pro_p95_ms,batch_p95_ms,"
          "preempts,resumes,parity")
    for r in rows:
        print(f"{r['cell']},{r['n_pro']},{r['pro_attainment']:.2f},"
              f"{r['pro_p95_ms']:.0f},{r['batch_p95_ms']:.0f},"
              f"{r['n_preemptions']},{r['n_resumes']},{r['parity']}")

    bad = [r for r in rows if not r["parity"]]
    if bad:
        raise SystemExit(f"greedy parity violated in {len(bad)} cells "
                         f"({[r['cell'] for r in bad]})")
    total_preempts = sum(
        r["n_preemptions"] for r in rows if r["policy"] == "slo")
    for load in cfg["loads"]:
        st, sl = _cell(rows, load, "static"), _cell(rows, load, "slo")
        gap = sl["pro_attainment"] - st["pro_attainment"]
        print(f"load {load:g}: pro SLO attainment "
              f"{st['pro_attainment']:.2f} (static) -> "
              f"{sl['pro_attainment']:.2f} (controller, "
              f"{sl['n_preemptions']} preemptions), gap +{gap:.2f}")
        if gap < cfg["min_attain_gap"]:
            raise SystemExit(
                f"load {load:g}: controller attainment gap {gap:.2f} < "
                f"{cfg['min_attain_gap']} over static")
    if total_preempts < cfg["min_preemptions"]:
        raise SystemExit(
            f"{total_preempts} preemptions fired < "
            f"{cfg['min_preemptions']} — the controller never actuated")

    with open(args.out, "w") as f:
        json.dump({"config": dict(cfg), "rows": rows}, f, indent=1)
    print(f"wrote {args.out} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
