"""Sharded multi-macro retrieval throughput: queries/sec vs n_shards and
batch size.

Sweeps ShardedDircIndex over shard counts and serving batch sizes on the
int_exact path (the production score path) and reports steady-state
queries/sec, plus the monolithic DircRagIndex baseline at each batch size.
Larger batches amortize dispatch exactly like the BatchScheduler's flushed
(b, dim) calls do in serving.

Run: PYTHONPATH=src python -m benchmarks.bench_sharded
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.retrieval import DircRagIndex, RetrievalConfig
from repro.core.sharded_index import ShardedDircIndex

N_DOCS = 4096
DIM = 256
K = 5
SHARDS = (1, 4, 8)
BATCHES = (1, 8, 32)
REPS = 10


def _measure(search, queries) -> float:
    """Steady-state seconds per search call (warmup excluded)."""
    search(queries).indices.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(REPS):
        search(queries).indices.block_until_ready()
    return (time.perf_counter() - t0) / REPS


def run() -> list[dict]:
    rng = np.random.default_rng(0)
    emb = jnp.asarray(rng.normal(size=(N_DOCS, DIM)).astype(np.float32))
    cfg = RetrievalConfig(bits=8, metric="cosine", path="int_exact")
    rows = []

    mono = DircRagIndex.build(emb, cfg)
    for b in BATCHES:
        q = jnp.asarray(rng.normal(size=(b, DIM)).astype(np.float32))
        dt = _measure(lambda x: mono.search(x, k=K), q)
        rows.append({"index": "monolithic", "n_shards": 0, "batch": b,
                     "qps": b / dt, "ms_per_call": dt * 1e3})

    for s in SHARDS:
        idx = ShardedDircIndex.build(emb, cfg, n_shards=s)
        for b in BATCHES:
            q = jnp.asarray(rng.normal(size=(b, DIM)).astype(np.float32))
            dt = _measure(lambda x: idx.search(x, k=K), q)
            rows.append({"index": "sharded", "n_shards": s, "batch": b,
                         "qps": b / dt, "ms_per_call": dt * 1e3})
    return rows


def main() -> None:
    rows = run()
    print(f"n_docs={N_DOCS} dim={DIM} k={K} path=int_exact "
          f"devices={len(jax.devices())}")
    print("index,n_shards,batch,qps,ms_per_call")
    for r in rows:
        print(f"{r['index']},{r['n_shards']},{r['batch']},"
              f"{r['qps']:.1f},{r['ms_per_call']:.3f}")


if __name__ == "__main__":
    main()
