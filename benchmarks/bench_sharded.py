"""Sharded multi-macro retrieval throughput: queries/sec vs n_shards and
batch size.

Sweeps ShardedDircIndex over shard counts and serving batch sizes on the
int_exact path (the production score path) and reports steady-state
queries/sec, plus the monolithic DircRagIndex baseline at each batch size.
Larger batches amortize dispatch exactly like the BatchScheduler's flushed
(b, dim) calls do in serving.

Run: PYTHONPATH=src python -m benchmarks.bench_sharded [--tiny]
         [--json BENCH_sharded.json]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.retrieval import DircRagIndex, RetrievalConfig
from repro.core.sharded_index import ShardedDircIndex

FULL = {"n_docs": 4096, "dim": 256, "k": 5, "shards": (1, 4, 8),
        "batches": (1, 8, 32), "reps": 10}
TINY = {"n_docs": 256, "dim": 128, "k": 3, "shards": (1, 2),
        "batches": (1, 8), "reps": 2}


def _measure(search, queries, reps: int) -> float:
    """Steady-state seconds per search call (warmup excluded)."""
    search(queries).indices.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        search(queries).indices.block_until_ready()
    return (time.perf_counter() - t0) / reps


def run(cfg_bench: dict = FULL) -> list[dict]:
    n_docs, dim, k = cfg_bench["n_docs"], cfg_bench["dim"], cfg_bench["k"]
    reps = cfg_bench["reps"]
    rng = np.random.default_rng(0)
    emb = jnp.asarray(rng.normal(size=(n_docs, dim)).astype(np.float32))
    cfg = RetrievalConfig(bits=8, metric="cosine", path="int_exact")
    rows = []

    mono = DircRagIndex.build(emb, cfg)
    for b in cfg_bench["batches"]:
        q = jnp.asarray(rng.normal(size=(b, dim)).astype(np.float32))
        dt = _measure(lambda x: mono.search(x, k=k), q, reps)
        rows.append({"index": "monolithic", "n_shards": 0, "batch": b,
                     "qps": b / dt, "ms_per_call": dt * 1e3})

    for s in cfg_bench["shards"]:
        idx = ShardedDircIndex.build(emb, cfg, n_shards=s)
        for b in cfg_bench["batches"]:
            q = jnp.asarray(rng.normal(size=(b, dim)).astype(np.float32))
            dt = _measure(lambda x: idx.search(x, k=k), q, reps)
            rows.append({"index": "sharded", "n_shards": s, "batch": b,
                         "qps": b / dt, "ms_per_call": dt * 1e3})
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true", help="CI smoke shapes")
    ap.add_argument("--json", default=None,
                    help="also write rows to this JSON path")
    args = ap.parse_args(argv)
    cfg_bench = TINY if args.tiny else FULL
    rows = run(cfg_bench)
    print(f"n_docs={cfg_bench['n_docs']} dim={cfg_bench['dim']} "
          f"k={cfg_bench['k']} path=int_exact devices={len(jax.devices())}")
    print("index,n_shards,batch,qps,ms_per_call")
    for r in rows:
        print(f"{r['index']},{r['n_shards']},{r['batch']},"
              f"{r['qps']:.1f},{r['ms_per_call']:.3f}")
    if args.json:
        cfg_json = {kk: list(v) if isinstance(v, tuple) else v
                    for kk, v in cfg_bench.items()}
        with open(args.json, "w") as f:
            json.dump({"config": cfg_json, "rows": rows}, f, indent=1)
        print(f"wrote {args.json} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
