"""Paper Fig. 6: effectiveness of the error-aware optimizations.

Ladder on one dataset (synth-scifact analogue), INT8, bit-serial path:
  error-free -> +errors naive map -> +grouped map -> +error-aware remap
  -> +Sigma-D detection (re-sense).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.error_model import ErrorModelConfig
from repro.core.retrieval import DircRagIndex, RetrievalConfig
from repro.core.topk import precision_at_k
from repro.data.synthetic import beir_analogue

ERR = ErrorModelConfig(enabled=True, p_min=5e-3, p_max=8e-2)


def run(k: int = 5) -> list:
    ds = beir_analogue("synth-scifact")
    docs = jnp.asarray(ds.doc_embeddings)
    qs = jnp.asarray(ds.query_embeddings)
    rel = jnp.asarray(ds.relevant)
    key = jax.random.key(0)

    ladder = [
        ("error-free", RetrievalConfig(bits=8, path="int_exact"), None),
        ("errors+naive-map", RetrievalConfig(
            bits=8, path="bitserial", mapping="interleaved", error=ERR,
            detect=False), key),
        ("errors+grouped-map", RetrievalConfig(
            bits=8, path="bitserial", mapping="grouped", error=ERR,
            detect=False), key),
        ("errors+error-aware-remap", RetrievalConfig(
            bits=8, path="bitserial", mapping="error_aware", error=ERR,
            detect=False), key),
        ("errors+remap+detection", RetrievalConfig(
            bits=8, path="bitserial", mapping="error_aware", error=ERR,
            detect=True, max_retries=3), key),
    ]
    rows = []
    for tag, cfg, kk in ladder:
        idx = DircRagIndex.build(docs, cfg)
        r = idx.search(qs, k=k, key=kk)
        rows.append({"config": tag,
                     "p_at_5": float(precision_at_k(r.indices, rel, k))})
    base = rows[0]["p_at_5"]
    naive = rows[1]["p_at_5"]
    remap = rows[3]["p_at_5"]
    for r in rows:
        r["recovered_frac"] = (
            (r["p_at_5"] - naive) / max(base - naive, 1e-9))
    rows.append({"config": "remap_improvement_pct",
                 "p_at_5": 100 * (remap - naive) / max(naive, 1e-9),
                 "recovered_frac": float("nan")})
    return rows


def main() -> None:
    print("config,p_at_5,recovered_frac_of_error_gap")
    for r in run():
        print(f"{r['config']},{r['p_at_5']:.4f},{r['recovered_frac']:.3f}")


if __name__ == "__main__":
    main()
