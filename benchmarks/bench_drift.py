"""Temporal drift vs the online recalibration loop (PR 9 tentpole).

The claim under test: a DIRC macro's error-aware bit-wise remapping is
extracted against a CALIBRATION-TIME error map, and `device_physics`
makes that map drift — amplitude ageing plus a slow spatial rotation of
the Fig. 5(a) profile. A stale mapping then leaves high-weight bits
sitting on cells that have gone bad, and nothing in the paper's offline
flow ever notices. The recalibration loop (`core/recalibration.py`)
closes this: Sigma-D detection counters -> weighted-exposure trigger ->
online map re-extraction -> fresh remapping -> in-place shard
re-encode, all while the index keeps serving.

Cells, per drift magnitude (equal dataset / channel / query stream):

  static   stale calibration-time mapping, detection OFF — the paper's
           offline flow left running under drift
  detect   stale mapping + Sigma-D detect/re-sense (transient-error
           scrubbing only; it cannot move bits off bad cells)
  recal    detection + the full RecalibrationController loop

Metric: retrieval precision@k against the ERROR-FREE ORACLE's own
top-k on the same index geometry (oracle = 1.0 by construction). This
measures exactly the ranking perturbation the error channel causes;
dataset-relative P@k hides it because cluster margins dwarf LSB noise.

The channel regime is deliberately steep (low base profile, heavy
log-normal jitter): a handful of terrible cells per macro that a fresh
error-aware mapping hides under weight-1 bit positions. Rotation
drags those cells under weight-8 positions — damage a remap can
recover (8:1 leverage) — while detection saturation stays partial so
the counter-driven re-extraction can still order cells. Gates (FULL):
the static cell degrades monotonically with drift magnitude, and at
every nonzero magnitude the recal cell recovers at least half of the
stale-map-vs-oracle precision gap.

Emits BENCH_drift.json (rows + config) for the CI perf artifact.

Run: PYTHONPATH=src python -m benchmarks.bench_drift [--tiny]
         [--out BENCH_drift.json]
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    DriftConfig,
    RecalibrationConfig,
    RecalibrationController,
    RetrievalConfig,
    ShardedDircIndex,
)
from repro.core.error_model import ErrorModelConfig
from repro.core.topk import precision_at_k
from repro.data.synthetic import make_ir_dataset

FULL = {
    "n_docs": 512,
    "dim": 64,
    "n_queries": 64,
    "n_clusters": 64,
    "doc_noise": 1.1,
    "relevant_per_query": 8,
    "data_seed": 7,
    "k": 10,
    "n_shards": 4,
    "bits": 8,
    "mapping": "error_aware",
    "p_min": 1e-4,
    "p_max": 1.2e-2,  # steep: jitter pushes the tail to the 0.5 clip
    "jitter_sigma": 2.0,
    "error_seed": 5,
    "max_retries": 3,
    "drift_mags": [0.0, 1.0, 2.0, 3.0],
    "amp_mu_total": 0.05,  # log-amplitude ageing over the whole horizon
    "rot_total": 0.6,  # quarter-turns over the whole horizon at mag 1
    "drift_seed": 11,
    "n_waves": 48,
    "eval_waves": 12,  # precision measured over the final waves
    "wave_dt": 1.0,
    "recal_window": 6,
    "trigger_ratio": 1.03,
    "min_detected": 64,
    "query_seed": 123,
    "min_recovered": 0.5,  # recal recovery of the static-vs-oracle gap
    "monotone_eps": 0.0,  # static must strictly degrade with mag
    "min_recals": 1,  # recal cell must actually fire at mag > 0
}

TINY = {
    **FULL,
    "n_docs": 128,
    "dim": 32,
    "n_queries": 16,
    "n_clusters": 16,
    "drift_mags": [0.0, 2.0],
    "n_waves": 10,
    "eval_waves": 4,
    "recal_window": 3,
    "min_detected": 8,
    "min_recovered": -10.0,  # smoke shapes are too noisy to gate
    "monotone_eps": 1.0,
    "min_recals": 0,
}

CELLS = ("static", "detect", "recal")


def _dataset(cfg: dict):
    ds = make_ir_dataset(
        "drift",
        n_docs=cfg["n_docs"],
        dim=cfg["dim"],
        n_queries=cfg["n_queries"],
        n_clusters=cfg["n_clusters"],
        doc_noise=cfg["doc_noise"],
        relevant_per_query=cfg["relevant_per_query"],
        seed=cfg["data_seed"],
    )
    return jnp.asarray(ds.doc_embeddings), jnp.asarray(ds.query_embeddings)


def _oracle_topk(docs, queries, cfg: dict) -> jax.Array:
    """The error-free index's own top-k — ground truth for every cell."""
    ocfg = RetrievalConfig(
        bits=cfg["bits"], path="bitserial", mapping=cfg["mapping"]
    )
    oidx = ShardedDircIndex.build(docs, ocfg, n_shards=cfg["n_shards"])
    return oidx.search(queries, k=cfg["k"]).indices


def _run_cell(cell: str, mag: float, docs, queries, rel, cfg: dict) -> dict:
    """One (cell, drift magnitude) trajectory: `n_waves` query waves on
    a simulated clock, precision averaged over the final `eval_waves`."""
    err = ErrorModelConfig(
        enabled=True,
        p_min=cfg["p_min"],
        p_max=cfg["p_max"],
        jitter_sigma=cfg["jitter_sigma"],
        seed=cfg["error_seed"],
    )
    rcfg = RetrievalConfig(
        bits=cfg["bits"],
        path="bitserial",
        mapping=cfg["mapping"],
        error=err,
        detect=cell != "static",
        max_retries=cfg["max_retries"],
    )
    horizon = cfg["n_waves"] * cfg["wave_dt"]
    drift = DriftConfig(
        enabled=mag > 0,
        amp_mu=cfg["amp_mu_total"] * mag / horizon,
        amp_sigma=0.0,
        rotate_rate=cfg["rot_total"] * mag / horizon,
        seed=cfg["drift_seed"],
    )
    now = [0.0]
    index = ShardedDircIndex.build(
        docs, rcfg, n_shards=cfg["n_shards"], drift=drift,
        clock=lambda: now[0],
    )
    controller = None
    if cell == "recal":
        controller = RecalibrationController(
            index,
            RecalibrationConfig(
                window=cfg["recal_window"],
                trigger_ratio=cfg["trigger_ratio"],
                min_detected=cfg["min_detected"],
            ),
        )
    key = jax.random.key(cfg["query_seed"])
    k = cfg["k"]
    precisions = []
    for wave in range(cfg["n_waves"]):
        now[0] += cfg["wave_dt"]
        res = index.search(queries, k=k, key=jax.random.fold_in(key, wave))
        if controller is not None:
            controller.poll()
        if wave >= cfg["n_waves"] - cfg["eval_waves"]:
            precisions.append(float(precision_at_k(res.indices, rel, k)))
    stats = index.stats()
    return {
        "cell": cell,
        "drift_mag": float(mag),
        "precision": float(np.mean(precisions)),
        "total_recals": int(stats["total_recals"]),
        "total_detected": int(stats["total_detected"]),
        "total_residual": int(stats["total_residual"]),
        "drift_amplitude": (
            float(np.mean(stats["shards"][0].get("drift_amplitude", 1.0)))
            if stats["drift_enabled"] else 1.0
        ),
    }


def run(cfg: dict) -> list[dict]:
    docs, queries = _dataset(cfg)
    rel = _oracle_topk(docs, queries, cfg)
    rows = []
    for mag in cfg["drift_mags"]:
        cell_rows = {}
        for cell in CELLS:
            row = _run_cell(cell, mag, docs, queries, rel, cfg)
            cell_rows[cell] = row
            rows.append(row)
        gap = 1.0 - cell_rows["static"]["precision"]
        for cell in CELLS:
            r = cell_rows[cell]
            r["oracle_gap"] = 1.0 - r["precision"]
            r["recovered_frac"] = (
                (r["precision"] - cell_rows["static"]["precision"]) / gap
                if gap > 1e-9 else 0.0
            )
    return rows


def _cell(rows: list[dict], cell: str, mag: float) -> dict:
    for r in rows:
        if r["cell"] == cell and r["drift_mag"] == mag:
            return r
    raise KeyError((cell, mag))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true", help="CI smoke shapes")
    ap.add_argument("--out", default="BENCH_drift.json")
    args = ap.parse_args(argv)
    cfg = TINY if args.tiny else FULL
    rows = run(cfg)

    print("cell,drift_mag,precision,oracle_gap,recovered_frac,recals")
    for r in rows:
        print(f"{r['cell']},{r['drift_mag']},{r['precision']:.4f},"
              f"{r['oracle_gap']:.4f},{r['recovered_frac']:+.2f},"
              f"{r['total_recals']}")

    mags = list(cfg["drift_mags"])
    statics = [_cell(rows, "static", m)["precision"] for m in mags]
    for lo, hi, p_lo, p_hi in zip(mags, mags[1:], statics, statics[1:]):
        if p_hi > p_lo + cfg["monotone_eps"]:
            raise SystemExit(
                f"static cell not monotone: mag {lo} -> {hi} precision "
                f"{p_lo:.4f} -> {p_hi:.4f}"
            )
    for mag in mags:
        if mag <= 0:
            continue
        r = _cell(rows, "recal", mag)
        if r["total_recals"] < cfg["min_recals"]:
            raise SystemExit(
                f"mag {mag}: recal loop never fired "
                f"({r['total_recals']} < {cfg['min_recals']})"
            )
        if r["recovered_frac"] < cfg["min_recovered"]:
            raise SystemExit(
                f"mag {mag}: recal recovered {r['recovered_frac']:.2f} "
                f"of the stale-vs-oracle gap < {cfg['min_recovered']}"
            )
    worst = _cell(rows, "recal", mags[-1])
    print(f"drift mag {mags[-1]}: static precision {statics[-1]:.4f}, "
          f"recal {worst['precision']:.4f} "
          f"(recovered {worst['recovered_frac']:.2f} of the oracle gap, "
          f"{worst['total_recals']} online recalibrations)")

    with open(args.out, "w") as f:
        json.dump({"config": dict(cfg), "rows": rows}, f, indent=1)
        f.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
