"""Paged vs fixed-slot KV cache at EQUAL cache HBM: mixed-length sweep.

The claim under test (PR 4 / ROADMAP "Serving memory model"): on a
bimodal prompt-length workload — RAG's signature mix of tiny queries and
long retrieval-augmented prompts — the paged engine turns the same cache
memory into >= 2x the concurrent sequences of fixed `cache_len` slots,
because short sequences only hold the blocks they actually use. And on a
uniform workload, where paging can't exploit length variance, decode
throughput must not regress.

Both engines get exactly `fixed_slots * cache_len` tokens of KV capacity:
the fixed engine as private per-slot regions, the paged engine as a
shared `n_blocks x block_size` pool (`serving/paged_cache.py`) with more
admission slots in front of it. Every cell replays the same greedy
request burst, asserts token parity against per-query
`GenerationEngine.generate`, and reports peak concurrent sequences,
decode tokens/sec, and TTFT percentiles (submit -> first token,
including queueing — the admission-capacity signal).

Compute runs in fp32 (`compute_dtype` override): fixed-slot and paged
attention are mathematically identical but round differently, and at
bf16 resolution an untrained smoke model throws enough logit near-ties
that strict token parity would flake. At fp32 the rounding gap is ~1e-7
against typical top-2 gaps of ~1e-3, so the parity assert is exact and
stable across XLA versions.

A third engine variant, `paged_kernel`, runs the same paged pool with
decode routed through the fused Pallas flash-decoding kernel
(`kernels/paged_attend.py`) instead of the dense-window gather: its rows
are the kernel-vs-gather column of the artifact, and it is held to the
same greedy token-parity gate as the other engines.

Emits BENCH_paged_cache.json (rows + config) for the CI perf artifact.

Run: PYTHONPATH=src python -m benchmarks.bench_paged_cache [--tiny]
         [--out BENCH_paged_cache.json]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving import ContinuousBatchingEngine, GenerationEngine
from repro.serving.paged_cache import blocks_for

FULL = {
    "arch": "phi4-mini-3.8b",
    "cache_len": 128,  # per-sequence capacity (fixed region / table cap)
    "fixed_slots": 4,  # fixed engine: 4 * 128 = 512 cache tokens
    "paged_slots": 12,  # paged engine: same 512 tokens as a shared pool
    "paged_slots_uniform": 10,  # pool / blocks-per-uniform-seq (see run())
    "block_size": 16,
    "prefill_chunk": 32,
    "n_requests": 24,
    "short_prompt": 8,
    "short_new": 8,
    "long_prompt": 96,
    "long_new": 32,
    "long_every": 4,  # every 4th request is long (bimodal mix)
    "uniform_prompt": 32,
    "uniform_new": 16,
    "repeats": 3,
    "min_uniform_tput": 0.85,
    "min_concurrency": 2.0,
}

TINY = {
    "arch": "phi4-mini-3.8b",
    "cache_len": 48,
    "fixed_slots": 2,  # 96 cache tokens
    "paged_slots": 8,
    "paged_slots_uniform": 4,
    "block_size": 8,
    "prefill_chunk": 16,
    "n_requests": 8,
    "short_prompt": 4,
    "short_new": 4,
    "long_prompt": 40,
    "long_new": 8,
    "long_every": 4,
    "uniform_prompt": 16,
    "uniform_new": 8,
    "repeats": 2,
    # tiny shapes: per-step overhead dominates and CI runners are noisy,
    # so the throughput gate only guards gross regressions
    "min_uniform_tput": 0.7,
    "min_concurrency": 2.0,
}


def _workload(bench_cfg: dict, kind: str) -> list[tuple[np.ndarray, int]]:
    """(prompt, max_new_tokens) bursts. `bimodal` interleaves one long
    RAG-style prompt into every `long_every` short queries; `uniform` is
    the degenerate equal-length case paging cannot exploit."""
    cfg = get_config(bench_cfg["arch"], smoke=True)
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(bench_cfg["n_requests"]):
        if kind == "bimodal" and (i + 1) % bench_cfg["long_every"] == 0:
            n, new = bench_cfg["long_prompt"], bench_cfg["long_new"]
        elif kind == "bimodal":
            n, new = bench_cfg["short_prompt"], bench_cfg["short_new"]
        else:
            n, new = bench_cfg["uniform_prompt"], bench_cfg["uniform_new"]
        reqs.append((rng.integers(0, cfg.vocab_size, size=n).astype(np.int32), new))
    return reqs


def _pool_tokens(bench_cfg: dict) -> int:
    return bench_cfg["fixed_slots"] * bench_cfg["cache_len"]


def _make_engine(model, params, bench_cfg: dict, paged: bool, kind: str,
                 paged_kernel: bool = False):
    """Equal-HBM engines. The fixed engine must provision every slot for
    the worst-case request (`cache_len`), which caps it at `fixed_slots`;
    the paged engine spends the same tokens as a shared pool and sizes
    its decode width to what the pool can sustain — `paged_slots` for the
    bimodal mix, `paged_slots_uniform` (pool // blocks-per-sequence) for
    the uniform workload, where extra static lanes would only burn
    compute the pool can never feed."""
    if paged:
        # +1: the reserved null block
        n_blocks = blocks_for(_pool_tokens(bench_cfg), bench_cfg["block_size"]) + 1
        slots_key = "paged_slots_uniform" if kind == "uniform" else "paged_slots"
        return ContinuousBatchingEngine(
            model,
            params,
            n_slots=bench_cfg[slots_key],
            cache_len=bench_cfg["cache_len"],
            paged=True,
            block_size=bench_cfg["block_size"],
            n_blocks=n_blocks,
            prefill_chunk=bench_cfg["prefill_chunk"],
            paged_kernel=paged_kernel or None,
        )
    return ContinuousBatchingEngine(
        model,
        params,
        n_slots=bench_cfg["fixed_slots"],
        cache_len=bench_cfg["cache_len"],
    )


def _bench_cell(engine, reqs, refs, repeats: int) -> dict:
    """Replay the burst `repeats` times; keep the best-throughput pass
    (CPU container timings are noisy; greedy outputs are identical)."""
    # warm-up: one full untimed replay, so every compiled shape the
    # workload will touch (paged decode-width and prefill-window buckets
    # included) exists before the clock starts
    for t in [engine.submit(p, max_new_tokens=new) for p, new in reqs]:
        t.result()
    best_tps, best = 0.0, None
    for _ in range(repeats):
        pre = engine.stats()
        t0 = time.perf_counter()
        tickets = [engine.submit(p, max_new_tokens=new) for p, new in reqs]
        engine.run_until_drained()
        dt = time.perf_counter() - t0
        outs = [np.asarray(t.result()) for t in tickets]
        tps = sum(len(o) for o in outs) / dt
        if tps > best_tps or best is None:
            # snapshot post NOW so step/occupancy deltas cover exactly
            # this pass, not every pass after it
            best_tps, best = tps, (tickets, outs, pre, engine.stats())
    tickets, outs, pre, post = best
    parity = all(np.array_equal(a, b) for a, b in zip(refs, outs))
    ttft_ms = np.asarray([t.first_token_s for t in tickets], np.float64) * 1e3
    n_steps = post["n_decode_steps"] - pre["n_decode_steps"]
    backpressure = post.get("n_backpressure", 0) - pre.get("n_backpressure", 0)
    occ_tok = 0
    for occ, n in post["occupancy_hist"].items():
        occ_tok += occ * (n - pre["occupancy_hist"].get(occ, 0))
    return {
        "n_backpressure": backpressure,
        "n_slots": engine.n_slots,
        "n_requests": len(reqs),
        "n_tokens": int(sum(len(o) for o in outs)),
        "tok_per_s": best_tps,
        "peak_active": post["peak_active"],
        "mean_occupancy": occ_tok / n_steps if n_steps else 0.0,
        "ttft_mean_ms": float(ttft_ms.mean()),
        "ttft_p95_ms": float(np.percentile(ttft_ms, 95)),
        "parity": parity,
    }


def run(bench_cfg: dict) -> list[dict]:
    cfg = dataclasses.replace(
        get_config(bench_cfg["arch"], smoke=True),
        compute_dtype="float32",
    )
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    baseline = GenerationEngine(model, params)
    repeats = bench_cfg.get("repeats", 3)

    rows = []
    for kind in ("bimodal", "uniform"):
        reqs = _workload(bench_cfg, kind)
        refs = []
        for p, new in reqs:
            out = baseline.generate(
                np.asarray(p)[None],
                max_new_tokens=new,
                cache_len=len(p) + new,
            )
            refs.append(np.asarray(out)[0])
        # third variant: same paged pool, decode through the fused Pallas
        # flash-decoding kernel instead of the dense-window gather — the
        # kernel-vs-gather column of the BENCH artifact
        for name, paged, kernel in (("fixed", False, False),
                                    ("paged", True, False),
                                    ("paged_kernel", True, True)):
            engine = _make_engine(model, params, bench_cfg, paged, kind,
                                  paged_kernel=kernel)
            row = _bench_cell(engine, reqs, refs, repeats)
            row["engine"] = name
            row["workload"] = kind
            row["cache_tokens"] = _pool_tokens(bench_cfg)
            # keep row schemas homogeneous across engines (BENCH contract)
            row["block_size"] = bench_cfg["block_size"] if paged else None
            row["prefill_chunk"] = bench_cfg["prefill_chunk"] if paged else None
            rows.append(row)
            engine.close()
    return rows


def _cell(rows, engine: str, workload: str) -> dict:
    for r in rows:
        if r["engine"] == engine and r["workload"] == workload:
            return r
    raise KeyError((engine, workload))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true", help="CI smoke shapes")
    ap.add_argument("--out", default="BENCH_paged_cache.json")
    args = ap.parse_args(argv)
    cfg = TINY if args.tiny else FULL
    rows = run(cfg)

    print("engine,workload,n_slots,peak,tok_per_s,ttft_ms,ttft_p95,parity")
    for r in rows:
        line = (
            f"{r['engine']},{r['workload']},{r['n_slots']},{r['peak_active']},"
            f"{r['tok_per_s']:.0f},{r['ttft_mean_ms']:.1f},"
            f"{r['ttft_p95_ms']:.1f},{r['parity']}"
        )
        print(line)

    bad = [r for r in rows if not r["parity"]]
    if bad:
        raise SystemExit(f"greedy parity violated in {len(bad)} cells")
    peak_paged = _cell(rows, "paged", "bimodal")["peak_active"]
    peak_fixed = _cell(rows, "fixed", "bimodal")["peak_active"]
    conc = peak_paged / peak_fixed
    tput_paged = _cell(rows, "paged", "uniform")["tok_per_s"]
    tput_fixed = _cell(rows, "fixed", "uniform")["tok_per_s"]
    tput = tput_paged / tput_fixed
    msg = (
        f"bimodal concurrency: paged sustains {conc:.2f}x the fixed-slot"
        f" sequences at equal cache memory"
    )
    print(msg)
    print(f"uniform decode throughput: paged/fixed = {tput:.2f}x")
    # kernel-vs-gather: informational column (interpret-mode Pallas on CPU
    # is expected to trail the fused-XLA gather; the hard gate is parity,
    # which `bad` above enforces for kernel rows too)
    for kind in ("bimodal", "uniform"):
        kps = _cell(rows, "paged_kernel", kind)["tok_per_s"]
        gps = _cell(rows, "paged", kind)["tok_per_s"]
        print(f"{kind} decode throughput: kernel/gather = {kps / gps:.2f}x")
    if conc < cfg["min_concurrency"]:
        raise SystemExit(f"paged concurrency {conc:.2f}x < 2x fixed at equal memory")
    if tput < cfg["min_uniform_tput"]:
        raise SystemExit(f"paged uniform throughput regressed to {tput:.2f}x fixed")

    with open(args.out, "w") as f:
        json.dump({"config": dict(cfg), "rows": rows}, f, indent=1)
    print(f"wrote {args.out} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
