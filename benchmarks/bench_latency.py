"""Paper Table III: DIRC-RAG vs a von-Neumann baseline on SciFact-sized
retrieval (1.9 MB INT8, dim 512).

The paper compares against an RTX3090 (21.7 ms / 86.8 mJ per query). We
cannot measure a GPU here; we (a) reproduce the DIRC side from the
calibrated model, (b) measure THIS container's JAX-CPU retrieval as the
living von-Neumann baseline, and (c) quote the paper's GPU constants.
"""
from __future__ import annotations

import time

import jax.numpy as jnp

from repro.core.retrieval import DircRagIndex, RetrievalConfig
from repro.core.simulator import (RTX3090_ENERGY_J, RTX3090_LATENCY_S,
                                  simulate_database_mb)
from repro.data.synthetic import beir_analogue


def run() -> dict:
    ds = beir_analogue("synth-scifact")
    idx = DircRagIndex.build(jnp.asarray(ds.doc_embeddings),
                             RetrievalConfig(bits=8, path="int_exact"))
    qs = jnp.asarray(ds.query_embeddings)
    # warmup + measure
    idx.search(qs, k=3).indices.block_until_ready()
    t0 = time.perf_counter()
    reps = 5
    for _ in range(reps):
        idx.search(qs, k=3).indices.block_until_ready()
    cpu_per_query = (time.perf_counter() - t0) / (reps * qs.shape[0])

    sim = simulate_database_mb(1.9, dim=512, bits=8)
    return {
        "dirc_latency_us": sim.latency_s * 1e6,
        "dirc_energy_uj": sim.energy_j * 1e6,
        "paper_dirc_latency_us": 2.77,
        "paper_dirc_energy_uj": 0.46,
        "rtx3090_latency_us": RTX3090_LATENCY_S * 1e6,
        "rtx3090_energy_uj": RTX3090_ENERGY_J * 1e6,
        "jax_cpu_latency_us": cpu_per_query * 1e6,
        "speedup_vs_rtx3090": RTX3090_LATENCY_S / sim.latency_s,
        "speedup_vs_this_cpu": cpu_per_query / sim.latency_s,
    }


def main() -> None:
    r = run()
    print("metric,value")
    for k, v in r.items():
        print(f"{k},{v:.4g}")


if __name__ == "__main__":
    main()
