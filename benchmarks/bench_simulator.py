"""Paper Table I: the full DIRC-RAG spec from the calibrated model, plus
latency/energy scaling across database sizes and precisions."""
from __future__ import annotations

from repro.core.simulator import simulate_database_mb, table1_spec

PAPER = {
    "area_mm2": 6.18, "frequency_mhz": 250, "voltage": 0.8,
    "macro_area_mm2": 0.34, "macro_tops_per_w": 1176,
    "macro_tops_per_mm2": 24.9, "total_density_mb_per_mm2": 5.178,
    "retrieval_latency_us_4mb": 5.6, "energy_per_query_uj_4mb": 0.956,
    "throughput_tops": 131,
}


def run() -> dict:
    spec = table1_spec()
    rows = {"spec": spec, "paper": PAPER, "scaling": []}
    for mb in (0.5, 1.0, 1.9, 2.0, 4.0):
        for bits in (8, 4):
            rep = simulate_database_mb(mb, dim=512, bits=bits)
            rows["scaling"].append({
                "db_mb": mb, "bits": bits,
                "latency_us": rep.latency_s * 1e6,
                "energy_uj": rep.energy_j * 1e6,
            })
    return rows


def main() -> None:
    out = run()
    print("metric,model,paper,rel_err")
    for k, paper_v in PAPER.items():
        v = out["spec"][k]
        print(f"{k},{v:.4g},{paper_v},{abs(v - paper_v) / paper_v:.3f}")
    print("\ndb_mb,bits,latency_us,energy_uj")
    for r in out["scaling"]:
        print(f"{r['db_mb']},{r['bits']},{r['latency_us']:.3f},"
              f"{r['energy_uj']:.4f}")


if __name__ == "__main__":
    main()
