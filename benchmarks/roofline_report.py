"""Roofline report: render the dry-run JSONs into the EXPERIMENTS tables.

Reads experiments/dryrun/*.json (produced by repro.launch.dryrun) and
emits (a) the per-cell three-term roofline table, (b) the collective
breakdown, (c) the memory-fit table for both meshes.
"""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = "experiments/dryrun"


def load(dryrun_dir: str = DRYRUN_DIR) -> list:
    rows = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rows.append(json.load(open(f)))
    return rows


def roofline_rows(rows) -> list:
    out = []
    for r in rows:
        if r["status"] != "ok" or "roofline" not in r:
            continue
        rf = r["roofline"]
        out.append({
            "arch": r["arch"], "shape": r["shape"],
            "compute_s": rf["compute_s"], "memory_s": rf["memory_s"],
            "collective_s": rf["collective_s"],
            "bottleneck": rf["bottleneck"],
            "useful_flops_ratio": rf["useful_flops_ratio"],
            "step_bound_s": max(rf["compute_s"], rf["memory_s"],
                                rf["collective_s"]),
            "roofline_fraction": rf["compute_s"] / max(
                rf["compute_s"], rf["memory_s"], rf["collective_s"]),
            "temp_gib": r["memory"]["temp_gib"],
            "fits": r["memory"]["fits_16gib"],
        })
    return out


def main() -> None:
    rows = load()
    ok = [r for r in rows if r["status"] == "ok"]
    skipped = [r for r in rows if r["status"] == "skipped"]
    print(f"# cells: {len(rows)} ({len(ok)} ok, {len(skipped)} skipped)")
    print("\narch,shape,compute_s,memory_s,collective_s,bottleneck,"
          "useful_ratio,roofline_fraction,temp_gib,fits16gib")
    for r in roofline_rows(rows):
        print(f"{r['arch']},{r['shape']},{r['compute_s']:.4f},"
              f"{r['memory_s']:.4f},{r['collective_s']:.4f},"
              f"{r['bottleneck']},{r['useful_flops_ratio']:.3f},"
              f"{r['roofline_fraction']:.3f},{r['temp_gib']:.1f},"
              f"{r['fits']}")
    print("\nskipped_cell,reason")
    for r in skipped:
        print(f"{r['arch']}/{r['shape']}/{r['mesh']},"
              f"\"{r['reason'][:80]}\"")


if __name__ == "__main__":
    main()
