"""Walk the calibrated DIRC-RAG silicon model across the paper's design
space: database size, precision, dimension, detection on/off.

Run: PYTHONPATH=src python examples/edge_sim.py
"""
from repro.core.simulator import simulate_database_mb, table1_spec


def main() -> None:
    print("== Table I spec (calibrated model vs paper) ==")
    for k, v in table1_spec().items():
        print(f"   {k:32s} {v}")

    print("\n== latency/energy scaling (dim 512) ==")
    print(f"   {'MB':>5s} {'bits':>5s} {'us/query':>9s} {'uJ/query':>9s}")
    for mb in (0.25, 0.5, 1, 2, 4):
        for bits in (8, 4):
            r = simulate_database_mb(mb, dim=512, bits=bits)
            print(f"   {mb:5.2f} {bits:5d} {r.latency_s * 1e6:9.3f} "
                  f"{r.energy_j * 1e6:9.4f}")

    print("\n== dimension folding (4MB INT8) ==")
    for dim in (128, 256, 512, 1024):
        r = simulate_database_mb(4.0, dim=dim, bits=8)
        print(f"   dim {dim:5d}: {r.latency_s * 1e6:7.3f} us, "
              f"{r.plan.docs_per_core * 16:6d} docs resident")

    print("\n== error-detection cost (4MB INT8) ==")
    on = simulate_database_mb(4.0, detect=True)
    off = simulate_database_mb(4.0, detect=False)
    print(f"   detect ON : {on.latency_s * 1e6:.3f} us, "
          f"{on.energy_j * 1e6:.4f} uJ")
    print(f"   detect OFF: {off.latency_s * 1e6:.3f} us, "
          f"{off.energy_j * 1e6:.4f} uJ  "
          f"(saves {(1 - off.latency_s / on.latency_s) * 100:.1f}% latency)")


if __name__ == "__main__":
    main()
