"""End-to-end edge RAG serving: embed -> sharded DIRC retrieve -> augment ->
generate, with micro-batched requests against a small LM (paper Fig. 1,
scaled to a 4-macro ShardedDircIndex) plus live corpus updates.

Serving model (PR 2): the async scheduler replaces pull-based batching.
`pipe.scheduler(max_wait_ms=...)` starts a background flush loop with a
DUAL trigger — a batch is formed the moment `max_batch` tickets are
pending OR the oldest ticket has waited `max_wait_ms` — so the DIRC
macro sees full (b, dim) query-stationary batches under streaming
traffic while nobody blocks. Each `submit(..., tenant=...)` lands in a
per-tenant queue drained deficit-round-robin, so one chatty tenant
cannot starve others; `pipe.query_stream` wraps the same machinery as a
results-as-they-complete generator (and `aquery_stream` for asyncio).

Generation rides the same front door (PR 3): `pipe.query_stream(...,
generate=True)` submits each completed retrieval's augmented prompt into
a `ContinuousBatchingEngine` decode slot — sequences join and leave the
`n_slots`-wide decode batch at token boundaries (Orca/vLLM-style
continuous batching), so short answers never wait for long ones and the
batch stays full under streaming traffic. Tickets are futures with
`result()`, `done()`, `add_done_callback()` and a `token_stream()`
iterator for live per-token output; `pipe.generate_stream` is the
retrieval-free variant and `pipe.decode_engine()` hands out the engine
directly. For offered-load sweeps run the open-loop benches:

  PYTHONPATH=src python -m repro.launch.serve --rag --open-loop \
      --offered-qps 500 --n-tenants 4 --skew 10 --max-wait-ms 5
  PYTHONPATH=src python -m repro.launch.serve --rag --open-loop \
      --generate --offered-qps 20 --rag-queries 32 --new-tokens 16
  PYTHONPATH=src python -m benchmarks.bench_async_serving
  PYTHONPATH=src python -m benchmarks.bench_continuous_batching

Run: PYTHONPATH=src python examples/rag_serve.py
"""
import time

import jax

from repro.configs import get_config
from repro.core.retrieval import RetrievalConfig
from repro.models import build_model
from repro.serving import EngineConfig, HashEmbedder, RagPipeline

CORPUS = [
    "DIRC couples a multi-level ReRAM subarray with an SRAM cell.",
    "The query-stationary dataflow pins the query in input registers.",
    "Sixteen cores each run a local top-k comparator.",
    "Bit-wise remapping puts MSBs in the most reliable ReRAM positions.",
    "The Sigma-D LUT detects sensing errors and triggers re-sensing.",
    "INT8 quantized embeddings retrieve almost as well as FP32.",
    "The macro reaches 1176 TOPS/W at 250 MHz and 0.8 V.",
    "A 4MB database is searched in 5.6 microseconds per query.",
] + [f"filler document number {i} about unrelated topics" for i in range(56)]


def main() -> None:
    print("== loading generator (phi4-mini smoke config) ==")
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    print("== building 4-shard RAG pipeline over", len(CORPUS), "documents ==")
    pipe = RagPipeline(
        CORPUS,
        RetrievalConfig(bits=8, metric="cosine", path="int_exact"),
        model=model, params=params,
        dim=256, embedder=HashEmbedder(dim=256),
        max_prompt_len=96,
        n_shards=4,
    )
    print("   shard loads:", pipe.index.shard_loads())

    queries = [
        "how does the error detection work?",
        "what dataflow does DIRC use for retrieval?",
        "how fast is a 4MB database search?",
    ]
    t0 = time.time()
    results = pipe.query_many(queries, k=2, max_new_tokens=12)
    for q, res in zip(queries, results):
        print(f"\nQ: {q}")
        for i, t in zip(res.doc_ids, res.retrieved_texts):
            print(f"   retrieved[{i}]: {t[:70]}")
        print(f"   DIRC sim: {res.sim_latency_us:.2f} us, "
              f"{res.sim_energy_uj:.3f} uJ per query")
        print(f"   generated {res.answer_tokens.shape[1]} tokens "
              f"(untrained model -> noise)")
    print(f"\nbatched wave of {len(queries)} queries: "
          f"{time.time() - t0:.2f}s wall (ONE embed + ONE search)")

    print("\n== live corpus update: add a doc, retrieve it, tombstone it ==")
    new_ids = pipe.add_docs(
        ["The global comparator merges per-macro candidate lists by score."])
    res = pipe.query("who merges the per-macro candidate lists?", k=1,
                     max_new_tokens=0)
    print(f"   added id {new_ids[0]}, retrieved id {res.doc_ids[0]}: "
          f"{res.retrieved_texts[0][:60]}")
    pipe.delete_docs(new_ids.tolist())
    res = pipe.query("who merges the per-macro candidate lists?", k=1,
                     max_new_tokens=0)
    print(f"   after delete, retrieved id {res.doc_ids[0]} "
          f"(tombstone never returned)")

    print("\n== micro-batching scheduler (max_batch=2, pull-based) ==")
    sched = pipe.scheduler(max_batch=2)
    tickets = [sched.submit(q, k=1) for q in queries]
    print(f"   queued {sched.pending()} queries")
    sched.flush()
    for q, t in zip(queries, tickets):
        ids, scores = t.result()
        print(f"   [{ids[0]:3d}] score {scores[0]:+.3f}  <- {q}")
    print(f"   served {sched.n_served} queries in {sched.n_flushes} "
          f"batched flushes")

    print("\n== async scheduler (max_wait_ms=10, two tenants, no blocking) ==")
    sched = pipe.scheduler(max_batch=16, max_wait_ms=10.0)
    tickets = [sched.submit(q, k=1, tenant=f"user{i % 2}")
               for i, q in enumerate(queries)]
    # nobody calls result(): the background loop's deadline trigger fires
    for t in tickets:
        t.result(timeout=30.0)
    for t in tickets:
        print(f"   tenant {t.tenant}: [{t.doc_ids[0]:3d}] after "
              f"{t.wait_s * 1e3:.1f} ms (batch of {t.batch_size})")
    sched.close()

    print("\n== query_stream: results in completion order ==")
    for t in pipe.query_stream([("alice", q) for q in queries], k=1,
                               max_wait_ms=5.0):
        print(f"   {t.tenant}: [{t.doc_ids[0]:3d}] <- {t.text[:50]}")

    print("\n== continuous batching: retrieval chained into decode slots ==")
    # generate=True: each completed retrieval's augmented prompt joins the
    # n_slots-wide decode batch at the next token boundary; answers stream
    # back in completion order with TTFT/e2e stamps per ticket
    for t in pipe.query_stream(queries, k=2, max_wait_ms=5.0, generate=True,
                               max_new_tokens=8, config=EngineConfig(n_slots=2)):
        print(f"   slot {t.slot}: {len(t.tokens)} tokens in "
              f"{t.wait_s * 1e3:.0f} ms (TTFT {t.first_token_s * 1e3:.0f} ms)"
              f" <- {t.text[:40]}")

    print("\n== token_stream: live per-token consumption ==")
    engine = pipe.decode_engine(EngineConfig(n_slots=2), max_new_tokens=8,
                                start=True)
    try:
        prompt = pipe.encode_prompt(queries[0], [CORPUS[0]])
        ticket = engine.submit(prompt, max_new_tokens=8)
        toks = [tok for tok in ticket.token_stream(timeout=60.0)]
        print(f"   streamed {len(toks)} tokens one at a time: {toks}")
    finally:
        engine.close()


if __name__ == "__main__":
    main()
