"""End-to-end edge RAG serving: embed -> DIRC retrieve -> augment ->
generate, with batched requests against a small LM (paper Fig. 1).

Run: PYTHONPATH=src python examples/rag_serve.py
"""
import time

import jax

from repro.configs import get_config
from repro.core.retrieval import RetrievalConfig
from repro.models import build_model
from repro.serving import HashEmbedder, RagPipeline

CORPUS = [
    "DIRC couples a multi-level ReRAM subarray with an SRAM cell.",
    "The query-stationary dataflow pins the query in input registers.",
    "Sixteen cores each run a local top-k comparator.",
    "Bit-wise remapping puts MSBs in the most reliable ReRAM positions.",
    "The Sigma-D LUT detects sensing errors and triggers re-sensing.",
    "INT8 quantized embeddings retrieve almost as well as FP32.",
    "The macro reaches 1176 TOPS/W at 250 MHz and 0.8 V.",
    "A 4MB database is searched in 5.6 microseconds per query.",
] + [f"filler document number {i} about unrelated topics" for i in range(56)]


def main() -> None:
    print("== loading generator (phi4-mini smoke config) ==")
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    print("== building RAG pipeline over", len(CORPUS), "documents ==")
    pipe = RagPipeline(
        CORPUS,
        RetrievalConfig(bits=8, metric="cosine", path="int_exact"),
        model=model, params=params,
        dim=256, embedder=HashEmbedder(dim=256),
        max_prompt_len=96,
    )

    queries = [
        "how does the error detection work?",
        "what dataflow does DIRC use for retrieval?",
        "how fast is a 4MB database search?",
    ]
    for q in queries:
        t0 = time.time()
        res = pipe.query(q, k=2, max_new_tokens=12)
        print(f"\nQ: {q}")
        for i, t in zip(res.doc_ids, res.retrieved_texts):
            print(f"   retrieved[{i}]: {t[:70]}")
        print(f"   DIRC sim: {res.sim_latency_us:.2f} us, "
              f"{res.sim_energy_uj:.3f} uJ per query")
        print(f"   generated {res.answer_tokens.shape[1]} tokens "
              f"(wall {time.time() - t0:.2f}s, untrained model -> noise)")


if __name__ == "__main__":
    main()
