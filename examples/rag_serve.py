"""End-to-end edge RAG serving: embed -> sharded DIRC retrieve -> augment ->
generate, with micro-batched requests against a small LM (paper Fig. 1,
scaled to a 4-macro ShardedDircIndex) plus live corpus updates.

Run: PYTHONPATH=src python examples/rag_serve.py
"""
import time

import jax

from repro.configs import get_config
from repro.core.retrieval import RetrievalConfig
from repro.models import build_model
from repro.serving import HashEmbedder, RagPipeline

CORPUS = [
    "DIRC couples a multi-level ReRAM subarray with an SRAM cell.",
    "The query-stationary dataflow pins the query in input registers.",
    "Sixteen cores each run a local top-k comparator.",
    "Bit-wise remapping puts MSBs in the most reliable ReRAM positions.",
    "The Sigma-D LUT detects sensing errors and triggers re-sensing.",
    "INT8 quantized embeddings retrieve almost as well as FP32.",
    "The macro reaches 1176 TOPS/W at 250 MHz and 0.8 V.",
    "A 4MB database is searched in 5.6 microseconds per query.",
] + [f"filler document number {i} about unrelated topics" for i in range(56)]


def main() -> None:
    print("== loading generator (phi4-mini smoke config) ==")
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    print("== building 4-shard RAG pipeline over", len(CORPUS), "documents ==")
    pipe = RagPipeline(
        CORPUS,
        RetrievalConfig(bits=8, metric="cosine", path="int_exact"),
        model=model, params=params,
        dim=256, embedder=HashEmbedder(dim=256),
        max_prompt_len=96,
        n_shards=4,
    )
    print("   shard loads:", pipe.index.shard_loads())

    queries = [
        "how does the error detection work?",
        "what dataflow does DIRC use for retrieval?",
        "how fast is a 4MB database search?",
    ]
    t0 = time.time()
    results = pipe.query_many(queries, k=2, max_new_tokens=12)
    for q, res in zip(queries, results):
        print(f"\nQ: {q}")
        for i, t in zip(res.doc_ids, res.retrieved_texts):
            print(f"   retrieved[{i}]: {t[:70]}")
        print(f"   DIRC sim: {res.sim_latency_us:.2f} us, "
              f"{res.sim_energy_uj:.3f} uJ per query")
        print(f"   generated {res.answer_tokens.shape[1]} tokens "
              f"(untrained model -> noise)")
    print(f"\nbatched wave of {len(queries)} queries: "
          f"{time.time() - t0:.2f}s wall (ONE embed + ONE search)")

    print("\n== live corpus update: add a doc, retrieve it, tombstone it ==")
    new_ids = pipe.add_docs(
        ["The global comparator merges per-macro candidate lists by score."])
    res = pipe.query("who merges the per-macro candidate lists?", k=1,
                     max_new_tokens=0)
    print(f"   added id {new_ids[0]}, retrieved id {res.doc_ids[0]}: "
          f"{res.retrieved_texts[0][:60]}")
    pipe.delete_docs(new_ids.tolist())
    res = pipe.query("who merges the per-macro candidate lists?", k=1,
                     max_new_tokens=0)
    print(f"   after delete, retrieved id {res.doc_ids[0]} "
          f"(tombstone never returned)")

    print("\n== micro-batching scheduler (max_batch=2) ==")
    sched = pipe.scheduler(max_batch=2)
    tickets = [sched.submit(q, k=1) for q in queries]
    print(f"   queued {sched.pending()} queries")
    sched.flush()
    for q, t in zip(queries, tickets):
        ids, scores = t.result()
        print(f"   [{ids[0]:3d}] score {scores[0]:+.3f}  <- {q}")
    print(f"   served {sched.n_served} queries in {sched.n_flushes} "
          f"batched flushes")


if __name__ == "__main__":
    main()
