"""Train a ~100M-parameter LM for a few hundred steps on the synthetic
bigram corpus — the end-to-end training driver with checkpointing.

The model is a scaled-down granite-family decoder (~100M params with the
byte-level vocab). Loss should fall from ~6.2 toward the bigram entropy
floor (~3.1 nats).

Run: PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse

from repro.configs.base import ModelConfig
from repro.launch import train as train_mod

CFG_100M = ModelConfig(
    name="granite-100m", family="dense",
    n_layers=10, d_model=768, n_heads=12, n_kv_heads=4,
    d_ff=2048, vocab_size=4096, head_dim=64,
    norm="rmsnorm", mlp="swiglu", rope_style="standard",
    tie_embeddings=True, attn_chunk=256,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    print(f"model: {CFG_100M.param_count() / 1e6:.1f}M params")

    # monkey-patch the registry lookup so the driver trains THIS config
    import repro.configs.registry as reg
    orig = reg.get_config

    def patched_get_config(a, smoke=False):
        return CFG_100M if a == "granite-100m" else orig(a, smoke)

    reg.get_config = patched_get_config
    import repro.launch.train as t
    t.get_config = reg.get_config

    out = train_mod.train(
        "granite-100m", smoke=True, steps=args.steps, batch=args.batch,
        seq=args.seq, lr=3e-3, ckpt_dir=args.ckpt_dir, ckpt_every=100,
        async_ckpt=True, log_every=20)
    print(f"final loss: {out['final_loss']:.4f} "
          f"(start {out['losses'][0]:.4f}, bigram floor ~3.1)")


if __name__ == "__main__":
    main()
