"""Quickstart: build a DIRC-RAG index and query it, with and without
device errors — the paper's core loop in ~40 lines.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core.error_model import ErrorModelConfig
from repro.core.retrieval import DircRagIndex, RetrievalConfig
from repro.core.simulator import simulate_query
from repro.core.topk import precision_at_k
from repro.data.synthetic import make_ir_dataset


def main() -> None:
    print("== building synthetic corpus (4096 docs, dim 512) ==")
    ds = make_ir_dataset(n_docs=4096, dim=512, n_queries=64, seed=0)

    print("== clean INT8 retrieval (query-stationary digital CIM) ==")
    idx = DircRagIndex.build(
        jnp.asarray(ds.doc_embeddings),
        RetrievalConfig(bits=8, metric="cosine", path="int_exact"))
    res = idx.search(jnp.asarray(ds.query_embeddings), k=5)
    p5 = float(precision_at_k(res.indices, jnp.asarray(ds.relevant), 5))
    print(f"   P@5 = {p5:.3f}")
    print(f"   top-5 doc ids for query 0: {res.indices[0].tolist()}")

    print("== same retrieval under ReRAM sensing errors ==")
    noisy = DircRagIndex.build(
        jnp.asarray(ds.doc_embeddings),
        RetrievalConfig(
            bits=8, path="bitserial", mapping="error_aware",
            error=ErrorModelConfig(enabled=True, p_min=5e-3, p_max=8e-2),
            detect=True, max_retries=3))
    res_n = noisy.search(jnp.asarray(ds.query_embeddings), k=5,
                         key=jax.random.key(0))
    p5n = float(precision_at_k(res_n.indices, jnp.asarray(ds.relevant), 5))
    print(f"   P@5 with errors + remap + Sigma-D detection = {p5n:.3f}")

    print("== what the silicon would do (calibrated model) ==")
    sim = simulate_query(idx.n_docs, idx.dim, bits=8)
    print(f"   database: {sim.plan.db_bytes / 2**20:.2f} MB INT8")
    print(f"   latency:  {sim.latency_s * 1e6:.2f} us/query"
          f"   energy: {sim.energy_j * 1e6:.3f} uJ/query")
    print(f"   breakdown: {sim.energy_breakdown}")


if __name__ == "__main__":
    main()
